"""Gateway load study: thousands of asyncio connections, bursty zipf traffic.

The serving stack behind ``launch/gateway.py`` is threaded; this study
exercises the asyncio EDGE the way real traffic would: ``--connections``
concurrent :class:`~repro.launch.gateway.GatewayConnection`\\ s (the
default simulates 2000; each is a coroutine, so "thousands of users" is a
scheduling statement, not a thread count), each sending bursts of
requests separated by idle lulls, kernel choice zipf-skewed so a few
(tenant, kernel) streams dominate — the same traffic shape the sharded
studies use, now arriving through the async front door.

What the study asserts (the edge-backpressure claims, enforced):

* The fleet's undelivered depth NEVER exceeds the configured edge bound:
  ``peak_fleet_tiles <= max_fleet_tiles * widen_factor`` — shedding /
  edge-parking engages BEFORE fleet queue depth passes the bound, so an
  arbitrarily large connection count cannot bloat the in-fleet queue
  (and with it every tenant's latency tail).
* Under deliberate overload (offered load >> bound) the edge actually
  fires: at least one request is shed (``overflow="shed"``) or parked
  (``overflow="wait"``).
* ZERO TICKET LOSS: every request that was admitted to the fleet comes
  back — delivered count equals the gateway's ``edge_submitted``.
* Spot-checked parity: a sample of delivered outputs matches the
  ``dfg_eval`` oracle (the soak test in tests/test_gateway.py does the
  exhaustive bit-parity version against the single-bank oracle).

``--autoscale`` attaches a ``PressureAutoscaler`` so the
backpressure-autoscaler coupling is live: while a scale-up is pending
the admission windows widen (reported as ``widened_ticks``), and at
``max_replicas`` saturation the edge sheds instead of queueing inside
the fleet.

``--loopback`` re-runs the SAME study over a real socket on 127.0.0.1
(``launch/socket_gateway.py``): every client is a
``RemoteOverlayClient`` speaking the length-prefixed frame protocol,
and the row reports the FRAMING TAX — in-process rps / loopback rps —
from an in-process arm run first with identical traffic.  All the
asserts above still hold over the wire (headline metric:
``loopback_rps``).

``--smoke`` shrinks everything for CI; ``--json PATH`` dumps the row for
``tools/bench_trajectory.py`` (headline metric: ``gateway_rps``).

Run: PYTHONPATH=src python -m benchmarks.gateway_load
     JAX_DEVICES=2 PYTHONPATH=src python -m benchmarks.gateway_load \
         --autoscale --smoke --json artifacts/bench/gateway.json
     PYTHONPATH=src python -m benchmarks.gateway_load --loopback \
         --smoke --json artifacts/bench/loopback.json
Reading the output: docs/SERVING.md#the-socket-transport.
"""

import argparse
import asyncio
import json
import os

# must run before jax initialises (mirrors tests/conftest.py)
_n = os.environ.get("JAX_DEVICES", "")
_FLAG = "--xla_force_host_platform_device_count"
if _n.isdigit() and int(_n) > 1 and _FLAG not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} {_FLAG}={int(_n)}".strip())

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overlay import compile_program
from repro.core.paper_bench import BENCH_NAMES, benchmark
from repro.core.vm import dfg_eval
from repro.launch.gateway import GatewayOverloadedError, OverlayGateway

BATCHES = (64, 128, 256)
PARITY_SAMPLE = 0.05        # fraction of delivered requests oracle-checked


def _make_kernels():
    return {n: compile_program(benchmark(n))
            for n in BENCH_NAMES + ("gradient",)}


def _zipf_probs(n, s=1.3):
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = 1.0 / ranks ** s
    return p / p.sum()


class _ClientStats:
    """Aggregated across all client coroutines (single-threaded loop)."""

    def __init__(self):
        self.delivered = 0
        self.shed_retries = 0
        self.parity_checked = 0
        self.parity_failures = []


async def _client(connect, kernels, stats, *, cid, bursts, burst_size,
                  seed, lull_s):
    """One connection's life: bursts of zipf-skewed submits, await the
    burst's results, idle, repeat.  Shed requests retry after the hint —
    offered load stays offered, so the edge counters reflect pressure,
    not abandonment.  ``connect(tenant, session)`` yields either an
    in-process ``GatewayConnection`` or a socket
    ``RemoteOverlayClient`` — the surface is identical."""
    rng = np.random.RandomState(seed)
    names = list(kernels)
    p = _zipf_probs(len(names))
    rot = names[cid % len(names):] + names[:cid % len(names)]
    async with connect(f"tenant{cid}", f"conn-{cid}") as conn:
        for _b in range(bursts):
            reqs = {}
            for _r in range(burst_size):
                k = kernels[rot[rng.choice(len(names), p=p)]]
                b = int(BATCHES[rng.randint(len(BATCHES))])
                xs = [rng.uniform(-2, 2, (b,)).astype(np.float32)
                      for _ in k.dfg.inputs]
                while True:
                    try:
                        t = await conn.submit(k, xs)
                        break
                    except GatewayOverloadedError as e:
                        stats.shed_retries += 1
                        await asyncio.sleep(max(e.retry_after, 1e-4))
                reqs[t] = (k, xs)
            async for t, outs in conn.results():
                stats.delivered += 1
                if rng.rand() < PARITY_SAMPLE:
                    _parity_check(stats, *reqs[t], outs)
            if lull_s:
                await asyncio.sleep(rng.uniform(0, lull_s))


def _parity_check(stats, k, xs, outs):
    stats.parity_checked += 1
    ref = dfg_eval(k.dfg, {m: jnp.asarray(v)
                           for m, v in zip(k.dfg.inputs, xs)})
    for o, y in zip(k.dfg.outputs, outs):
        got, want = np.asarray(y), np.asarray(ref[o])
        if not np.allclose(got, want, rtol=1e-6, atol=1e-6):
            stats.parity_failures.append(
                (k.dfg.name, o, float(np.abs(got - want).max())))


async def _drive(connect, kernels, args):
    stats = _ClientStats()
    t0 = time.perf_counter()
    await asyncio.gather(*(
        _client(connect, kernels, stats, cid=i, bursts=args.bursts,
                burst_size=args.burst_size, seed=args.seed * 7919 + i,
                lull_s=args.lull)
        for i in range(args.connections)))
    wall = time.perf_counter() - t0
    return stats, wall


async def _overload_probe(connect, bound, kernels):
    """Deterministically saturate the edge: fire 4x the depth bound's
    worth of tiles in one ``gather`` — submits hit the capacity check
    back-to-back on the event loop, orders of magnitude faster than any
    drain, so the edge MUST shed (``overflow="shed"``) or park
    (``overflow="wait"``) before fleet depth can pass the bound.  Returns
    (admitted, delivered) so the zero-loss check covers the probe too."""
    k = kernels[next(iter(kernels))]
    n = max(8, 2 * bound)                   # batch-256 => 2 tiles each
    async with connect("probe", "probe") as conn:
        async def one():
            xs = [np.zeros((256,), np.float32) for _ in k.dfg.inputs]
            try:
                return await conn.submit(k, xs)
            except GatewayOverloadedError:
                return None
        tickets = await asyncio.gather(*(one() for _ in range(n)))
        delivered = await conn.drain()
        return sum(t is not None for t in tickets), len(delivered)


def run_study(args) -> dict:
    kernels = _make_kernels()
    gw = OverlayGateway.local(
        n_replicas=args.replicas, autoscale=args.autoscale,
        max_replicas=args.max_replicas,
        bank_capacity=args.bank,
        max_fleet_tiles=args.max_fleet_tiles,
        widen_factor=args.widen_factor,
        overflow=args.overflow)

    def connect(tenant, session):
        return gw.connect(tenant=tenant, session=session)

    async def main():
        async with gw:
            # warmup: one request per kernel compiles the dispatch bucket
            # outside the timed window
            async with gw.connect(tenant="warmup") as conn:
                for k in kernels.values():
                    xs = [np.zeros((BATCHES[0],), np.float32)
                          for _ in k.dfg.inputs]
                    await conn.submit(k, xs)
                await conn.drain()
            n_warm = gw.n_submitted
            stats, wall = await _drive(connect, kernels, args)
            # untimed: force the edge to actually fire, whatever the
            # drain rate of this machine made of the timed window
            admitted, got = await _overload_probe(
                connect, gw.max_fleet_tiles, kernels)
            stats.delivered += got
            return stats, wall, gw.stats(), n_warm, (admitted, got)

    stats, wall, gstats, n_warm, probe = asyncio.run(main())
    n_requests = args.connections * args.bursts * args.burst_size
    row = {
        "connections": args.connections,
        "replicas": args.replicas,
        "devices": jax.device_count(),
        "autoscale": args.autoscale,
        "max_replicas": args.max_replicas if args.autoscale else None,
        "requests": n_requests,
        "delivered": stats.delivered,
        "gateway_rps": stats.delivered / wall,
        "wall_s": wall,
        "max_fleet_tiles": args.max_fleet_tiles,
        "widen_factor": args.widen_factor,
        "overflow": args.overflow,
        "n_shed": gstats["edge_shed"],
        "shed_retries": stats.shed_retries,
        "n_edge_queued": gstats["edge_queued"],
        "peak_edge_waiters": gstats["peak_edge_waiters"],
        "peak_fleet_tiles": gstats["peak_fleet_tiles"],
        "widened_ticks": gstats["widened_ticks"],
        "edge_submitted": gstats["edge_submitted"] - n_warm,
        "parity_checked": stats.parity_checked,
        "probe_admitted": probe[0],
        "probe_delivered": probe[1],
    }
    if args.autoscale:
        fleet = gstats["fleet"]
        row["scale_ups"] = fleet.get("scale_ups", 0)
        row["scale_downs"] = fleet.get("scale_downs", 0)
    return row, stats


def run_loopback_study(args) -> dict:
    """The same study over a real socket on 127.0.0.1.

    Runs the in-process arm first (identical traffic parameters) for the
    baseline, then drives every client as a ``RemoteOverlayClient``
    against one ``OverlaySocketServer``.  The row's headline is
    ``loopback_rps``; ``framing_tax = inproc_rps / loopback_rps`` is the
    cost of length-prefixed frames + codec + TCP loopback relative to
    same-process awaits.  Wire counters come from the server's
    ``stats()`` so the JSON row doubles as a framing-overhead ledger.
    """
    from repro.launch.socket_gateway import (
        OverlaySocketServer,
        RemoteOverlayClient,
    )
    from repro.launch.transport import CODECS

    inproc_row, _ = run_study(args)

    kernels = _make_kernels()
    gw = OverlayGateway.local(
        n_replicas=args.replicas, autoscale=args.autoscale,
        max_replicas=args.max_replicas,
        bank_capacity=args.bank,
        max_fleet_tiles=args.max_fleet_tiles,
        widen_factor=args.widen_factor,
        overflow=args.overflow)

    async def main():
        async with gw:
            async with OverlaySocketServer(gw) as srv:
                def connect(tenant, session):
                    return RemoteOverlayClient(
                        "127.0.0.1", srv.port,
                        tenant=tenant, session=session)
                # warmup: compiles dispatch buckets AND registers every
                # kernel server-side, so the timed window sends key-only
                # submits (register-once is part of what we measure FOR,
                # not what we measure)
                async with connect("warmup", "warmup") as conn:
                    for k in kernels.values():
                        xs = [np.zeros((BATCHES[0],), np.float32)
                              for _ in k.dfg.inputs]
                        await conn.submit(k, xs)
                    await conn.drain()
                n_warm = gw.n_submitted
                stats, wall = await _drive(connect, kernels, args)
                admitted, got = await _overload_probe(
                    connect, gw.max_fleet_tiles, kernels)
                stats.delivered += got
                return (stats, wall, gw.stats(), srv.stats(), n_warm,
                        (admitted, got))

    stats, wall, gstats, sstats, n_warm, probe = asyncio.run(main())
    n_requests = args.connections * args.bursts * args.burst_size
    loopback_rps = stats.delivered / wall
    row = {
        "connections": args.connections,
        "replicas": args.replicas,
        "devices": jax.device_count(),
        "autoscale": args.autoscale,
        "requests": n_requests,
        "delivered": stats.delivered,
        "loopback_rps": loopback_rps,
        "inproc_rps": inproc_row["gateway_rps"],
        "framing_tax": inproc_row["gateway_rps"] / loopback_rps,
        "codec": CODECS[0],
        "wall_s": wall,
        "max_fleet_tiles": args.max_fleet_tiles,
        "widen_factor": args.widen_factor,
        "overflow": args.overflow,
        "n_shed": gstats["edge_shed"],
        "shed_retries": stats.shed_retries,
        "n_edge_queued": gstats["edge_queued"],
        "peak_fleet_tiles": gstats["peak_fleet_tiles"],
        "edge_submitted": gstats["edge_submitted"] - n_warm,
        "parity_checked": stats.parity_checked,
        "probe_admitted": probe[0],
        "probe_delivered": probe[1],
        "wire_frames_in": sstats["wire_frames_in"],
        "wire_frames_out": sstats["wire_frames_out"],
        "wire_bytes_in": sstats["wire_bytes_in"],
        "wire_bytes_out": sstats["wire_bytes_out"],
        "wire_connections": sstats["wire_connections"],
        "wire_registers": sstats["wire_registers"],
        "wire_rejects": sstats["wire_rejects"],
        "wire_reparked": sstats["wire_reparked"],
    }
    return row, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--connections", type=int, default=2000)
    ap.add_argument("--bursts", type=int, default=2,
                    help="bursts per connection")
    ap.add_argument("--burst-size", type=int, default=2,
                    help="requests per burst")
    ap.add_argument("--lull", type=float, default=0.01,
                    help="max idle seconds between a connection's bursts")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--bank", type=int, default=6)
    ap.add_argument("--max-fleet-tiles", type=int, default=64,
                    help="edge backpressure bound (dispatch tiles)")
    ap.add_argument("--widen-factor", type=float, default=2.0)
    ap.add_argument("--overflow", choices=("wait", "shed"),
                    default="shed")
    ap.add_argument("--autoscale", action="store_true")
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loopback", action="store_true",
                    help="drive the study over a 127.0.0.1 socket and "
                         "report the framing tax vs an in-process arm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer connections/requests)")
    ap.add_argument("--json", dest="json_path", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.connections = min(args.connections, 200)
        args.bursts = 1
        args.burst_size = min(args.burst_size, 2)
        args.lull = 0.0
    if args.loopback:
        # every client is a real TCP connection in loopback mode; stay
        # comfortably under default fd limits (both arms use this count,
        # so the framing-tax comparison is apples-to-apples)
        args.connections = min(args.connections, 256)

    if args.loopback:
        row, stats = run_loopback_study(args)
        print("connections,replicas,devices,codec,loopback_rps,"
              "inproc_rps,framing_tax,wire_frames_in,wire_bytes_out")
        print(f"{row['connections']},{row['replicas']},{row['devices']},"
              f"{row['codec']},{row['loopback_rps']:.1f},"
              f"{row['inproc_rps']:.1f},{row['framing_tax']:.2f},"
              f"{row['wire_frames_in']},{row['wire_bytes_out']}")
        print(f"# {row['connections']} socket clients pushed "
              f"{row['delivered']} requests at {row['loopback_rps']:.1f} "
              f"req/s over 127.0.0.1 ({row['codec']} frames, "
              f"{row['wire_bytes_in'] + row['wire_bytes_out']} wire "
              f"bytes); framing tax x{row['framing_tax']:.2f} vs "
              f"{row['inproc_rps']:.1f} req/s in-process; edge shed "
              f"{row['n_shed']} (retried {row['shed_retries']}); "
              f"{row['parity_checked']} results oracle-checked")
    else:
        row, stats = run_study(args)
        print("connections,replicas,devices,gateway_rps,n_shed,"
              "n_edge_queued,peak_fleet_tiles,widened_ticks")
        print(f"{row['connections']},{row['replicas']},{row['devices']},"
              f"{row['gateway_rps']:.1f},{row['n_shed']},"
              f"{row['n_edge_queued']},{row['peak_fleet_tiles']},"
              f"{row['widened_ticks']}")
        print(f"# {row['connections']} async connections pushed "
              f"{row['delivered']} requests at {row['gateway_rps']:.1f} "
              f"req/s through a {row['replicas']}-replica fleet; edge "
              f"shed {row['n_shed']} (retried {row['shed_retries']}), "
              f"parked {row['n_edge_queued']}, fleet depth peaked at "
              f"{row['peak_fleet_tiles']}/{row['max_fleet_tiles']} tiles "
              f"(window x{row['widen_factor']:g} while scaling); "
              f"{row['parity_checked']} results oracle-checked")

    if args.json_path:
        os.makedirs(os.path.dirname(args.json_path) or ".",
                    exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(row, f, indent=1)
        print(f"# wrote {args.json_path}")

    # ---- the claims this study exists for ---------------------------------
    assert not stats.parity_failures, (
        "gateway results diverged from the dfg_eval oracle",
        stats.parity_failures[:5])
    assert row["delivered"] == row["edge_submitted"], (
        "ticket loss: delivered != admitted",
        row["delivered"], row["edge_submitted"])
    assert row["probe_admitted"] == row["probe_delivered"], (
        "ticket loss in the overload probe",
        row["probe_admitted"], row["probe_delivered"])
    bound = row["max_fleet_tiles"] * row["widen_factor"]
    assert row["peak_fleet_tiles"] <= bound, (
        "fleet depth exceeded the edge bound — shedding engaged too late",
        row["peak_fleet_tiles"], bound)
    assert row["n_shed"] + row["n_edge_queued"] >= 1, (
        "the overload probe saturated the edge but it never shed or "
        "parked", row)
    if args.loopback:
        assert row["wire_rejects"] == 0, (
            "well-formed clients must never trip the server's frame "
            "rejection path", row["wire_rejects"])
        assert row["framing_tax"] > 0.0, row
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
