"""Roofline assembly: read dry-run artifacts -> per-cell three-term table.

Primary FLOPs/collective numbers come from the SPATIAL dry-run (layer
stacks unrolled => XLA cost analysis and HLO-text collective parsing see
every layer; scan-mode while bodies are counted once by HloCostAnalysis).
Memory-fit numbers (argument/temp bytes per device) come from the TM
dry-run (the deployed execution mode).
"""

import glob
import json
import os

from repro.launch import dryrun as D


def load(outdir):
    recs = {}
    for f in glob.glob(os.path.join(outdir, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def table(spatial_dir="artifacts/dryrun_spatial", tm_dir="artifacts/dryrun"):
    sp = load(spatial_dir)
    tm = load(tm_dir)
    rows = []
    for (arch, shape, mesh), r in sorted(tm.items()):
        if mesh != "single":
            continue
        key = (arch, shape, mesh)
        use = sp.get(key, r)
        if "skipped" in r:
            rows.append({"arch": arch, "shape": shape,
                         "skipped": r["skipped"]})
            continue
        if "error" in use:
            use = r
        if "error" in use:
            rows.append({"arch": arch, "shape": shape,
                         "error": use["error"]})
            continue
        rf = use.get("roofline", {})
        mem = r.get("memory", {})
        terms = {k: rf.get(f"t_{k}_s") for k in
                 ("compute", "memory", "collective")}
        dom = max((v, k) for k, v in terms.items() if v is not None)[1]
        peak = rf.get("model_flops_per_device", 0) / D.PEAK_FLOPS
        denom = max(v for v in terms.values() if v is not None)
        rows.append({
            "arch": arch, "shape": shape,
            "t_compute_s": terms["compute"],
            "t_memory_s": terms["memory"],
            "t_collective_s": terms["collective"],
            "bottleneck": dom,
            "model_flops": rf.get("model_flops_total"),
            "useful_ratio": rf.get("useful_flops_ratio"),
            "roofline_fraction": peak / denom if denom else None,
            "hbm_args_gb": mem.get("argument_size_in_bytes", 0) / 2 ** 30,
            "hbm_temp_gb": mem.get("temp_size_in_bytes", 0) / 2 ** 30,
            "source": "spatial" if key in sp and "error" not in sp[key]
                      else "tm",
        })
    return rows


def main():
    rows = table()
    cols = ("arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
            "bottleneck", "roofline_fraction", "useful_ratio",
            "hbm_temp_gb", "source")
    print(",".join(cols))
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']},{r['shape']},SKIP({r['skipped'][:40]})")
            continue
        if "error" in r:
            print(f"{r['arch']},{r['shape']},ERROR")
            continue
        print(",".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols))


if __name__ == "__main__":
    main()
