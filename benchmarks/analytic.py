"""Analytic roofline model: exact workload formulas per (arch x shape).

Why this exists: XLA's HloCostAnalysis counts while-loop bodies ONCE, so
scan-based (time-multiplexed) programs under-report FLOPs/bytes by the
trip count, and CPU-backend 'bytes accessed' over-reports fused traffic.
The spatial dry-run fixes the layer loop but not the inner flash/SSD chunk
scans.  These closed-form counts (validated against the spatial dry-run on
the dense archs, ratio ~0.9-1.1) are therefore the primary roofline
source; HLO-derived numbers are the cross-check.

All quantities are per device per step on the single-pod mesh
(dp x tp = 16 x 16), bf16 matmuls, f32 optimizer.
"""

from __future__ import annotations

import dataclasses

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS

DP, TP = 16, 16
BF16, F32 = 2, 4
SSD_CHUNK = 256


@dataclasses.dataclass
class CellModel:
    arch: str
    shape: str
    flops_dev: float          # per device per step
    mem_dev: float            # HBM bytes per device per step
    coll_dev: float           # wire bytes per device per step
    model_flops_dev: float    # 6/2 * N_active * tokens / chips

    @property
    def terms(self):
        return {"compute": self.flops_dev / PEAK_FLOPS,
                "memory": self.mem_dev / HBM_BW,
                "collective": self.coll_dev / LINK_BW}

    @property
    def bottleneck(self):
        t = self.terms
        return max(t, key=t.get)

    @property
    def step_time(self):
        """No-overlap roofline estimate: max of the three terms."""
        return max(self.terms.values())

    @property
    def mfu_at_roofline(self):
        return self.model_flops_dev / PEAK_FLOPS / self.step_time


def _per_block_flops(cfg, spec, ctx: float, S_q: int) -> float:
    """Forward FLOPs per *query token* for one block (whole model, pre-TP)."""
    D = cfg.d_model
    if spec.kind == "mamba":
        d = cfg.ssm
        din, N, H, G = d.d_inner, d.d_state, d.n_heads, d.n_groups
        f = 2 * D * (2 * din + 2 * G * N + H)          # in_proj
        f += 2 * d.d_conv * (din + 2 * G * N)          # conv
        q_bar = min(SSD_CHUNK, max(S_q, 1)) / 2        # intra-chunk keys
        f += H * (2 * q_bar * (N + d.head_dim)         # scores + y_diag
                  + 4 * N * d.head_dim)                # states + y_off
        f += 2 * din * D                               # out_proj
        return f
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f = 2 * D * (H + 2 * KH) * hd + 2 * H * hd * D     # qkv + out proj
    win_ctx = min(ctx, spec.window) if spec.window else ctx
    f += 4 * H * hd * win_ctx                          # scores + AV
    if spec.cross:
        f += 2 * D * (H + 2 * KH) * hd + 2 * H * hd * D + 4 * H * hd * ctx
    if spec.moe:
        f += 2 * D * cfg.n_experts                     # router
        f += cfg.top_k * 6 * D * cfg.expert_d_ff
        if cfg.n_shared_experts:
            f += 6 * D * cfg.shared_expert_d_ff
    else:
        f += 6 * D * cfg.d_ff
    return f


def _fwd_flops_per_token(cfg, ctx: float, S_q: int) -> float:
    total = 0.0
    for stack in cfg.stacks:
        for spec in stack.blocks:
            total += stack.count * _per_block_flops(cfg, spec, ctx, S_q)
    if cfg.encoder is not None:  # encoder processes its own S tokens
        for stack in cfg.encoder.stacks:
            for spec in stack.blocks:
                total += stack.count * _per_block_flops(cfg, spec, ctx, S_q)
    total += 2 * cfg.d_model * cfg.vocab               # head
    return total


def _param_bytes(cfg, dtype_bytes=F32) -> float:
    return cfg.param_count() * dtype_bytes


def _cache_bytes_total(cfg, B, S) -> float:
    total = 0.0
    for stack in cfg.stacks:
        for spec in stack.blocks:
            if spec.kind == "mamba":
                d = cfg.ssm
                total += stack.count * B * (
                    (d.d_conv - 1) * (d.d_inner + 2 * d.n_groups * d.d_state)
                    * BF16 + d.n_heads * d.d_state * d.head_dim * F32)
            else:
                W = min(S, spec.window) if spec.window else S
                total += stack.count * B * W * cfg.n_kv_heads \
                    * cfg.head_dim * BF16 * 2
                if spec.cross:
                    total += stack.count * B * S * cfg.n_kv_heads \
                        * cfg.head_dim * BF16 * 2
    return total


def cell_model(arch: str, shape: str, layout: str = "2d",
               mixed: bool = False, remat: str = "full") -> CellModel:
    """layout '2d' = DP16 x TP16 baseline; 'fsdp' = 256-way pure FSDP.
    mixed = bf16 params + f32 master (collectives run in bf16).
    remat 'full' = recompute everything (mult 4x fwd); 'dots' = save
    matmul outputs (mult ~3.15x fwd, activation HBM grows ~3x)."""
    cfg = get_config(arch)
    S, B, kind = SHAPES[shape]
    N = cfg.param_count()
    N_active = cfg.active_param_count()
    chips = DP * TP
    dp_eff, tp_eff = (chips, 1) if layout == "fsdp" else (DP, TP)
    WB = BF16 if mixed else F32     # wire dtype for weight gather/grad red.
    n_layers = cfg.n_layers

    if kind in ("train", "prefill"):
        tokens = B * (S - 1 if kind == "train" else S)
        ctx = S / 2                                     # causal average
        fwd = _fwd_flops_per_token(cfg, ctx, S) * tokens
        # active-expert correction: _fwd already uses top_k experts only
        if kind != "train":
            mult = 1.0
        elif remat == "dots":   # only elementwise recomputed in bwd
            mult = 3.15
        else:                   # bwd(2) + full remat recompute(1)
            mult = 4.0
        flops = fwd * mult
        flops_dev = flops / chips
        T_dev = tokens / dp_eff
        # memory: weights (gathered bf16, fwd+bwd) + opt traffic + act saves
        w_traffic = (2 if kind == "train" else 1) * N_active * BF16 / tp_eff
        opt = (8 * N * F32 / chips) if kind == "train" else 0.0
        act_mult = (4 if kind == "train" else 2) * \
            (3 if remat == "dots" else 1)
        acts = n_layers * T_dev * cfg.d_model * BF16 * act_mult
        mem_dev = w_traffic + opt + acts
        # collectives: fsdp gather (fwd + bwd-recompute) + grad red. + TP ARs
        # 'fsdp' layout gathers post-cast (bf16 wire, maybe_gather); the 2d
        # baseline gathers the stored dtype (f32 unless mixed — observed).
        gather_B = BF16 if layout == "fsdp" else WB
        # NB: weight gathers move ALL params (incl. inactive experts) — the
        # reason pure-FSDP regresses on MoE archs (keep experts sharded!)
        fsdp = (2 if kind == "train" else 1) * (N * gather_B / tp_eff) \
            * (dp_eff - 1) / dp_eff
        if kind != "train":
            grad_red = 0.0
        elif layout == "fsdp":   # ZeRO reduce-scatter only
            grad_red = (N * WB) * (dp_eff - 1) / dp_eff
        else:                    # ring all-reduce of the TP shard
            grad_red = 2 * (N * WB / tp_eff) * (dp_eff - 1) / dp_eff
        tp_ar = 0.0 if tp_eff == 1 else \
            n_layers * (4 if kind == "train" else 2) \
            * T_dev * cfg.d_model * BF16 * (tp_eff - 1) / tp_eff
        coll_dev = fsdp + grad_red + tp_ar
        model_flops = (6 if kind == "train" else 2) * N_active * tokens
    else:  # decode: one token per sequence, full cache attended
        tokens = B
        fwd = _fwd_flops_per_token(cfg, S, 1) * tokens
        flops = fwd
        flops_dev = flops / chips
        cache = _cache_bytes_total(cfg, B, S)
        # every data-row reads its TP shard of weights + its cache shard
        mem_dev = N_active * BF16 / TP + cache / chips + \
            tokens / DP * cfg.d_model * BF16 * n_layers * 2
        tp_ar = n_layers * 2 * (tokens / DP) * cfg.d_model * BF16 \
            * (TP - 1) / TP
        coll_dev = tp_ar
        model_flops = 2 * N_active * tokens
    return CellModel(arch, shape, flops_dev, mem_dev, coll_dev,
                     model_flops / chips)


def main():
    from repro.configs import ARCHS, skip_reason
    cols = ("arch,shape,t_compute_s,t_memory_s,t_collective_s,bottleneck,"
            "step_s,mfu_at_roofline")
    print(cols)
    for arch in ARCHS:
        for shape in SHAPES:
            if skip_reason(arch, shape):
                continue
            m = cell_model(arch, shape)
            t = m.terms
            print(f"{arch},{shape},{t['compute']:.4g},{t['memory']:.4g},"
                  f"{t['collective']:.4g},{m.bottleneck},{m.step_time:.4g},"
                  f"{m.mfu_at_roofline:.3f}")


if __name__ == "__main__":
    main()
