"""Benchmark harness: one module per paper table + system-level analogues.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus each
table's own CSV block.  Run: PYTHONPATH=src python -m benchmarks.run
"""

import time


def _timeit(fn, iters=3):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def main() -> None:
    from benchmarks import (area_analogue, context_switch, fig5_fus,
                            multi_tenant, roofline, table1_schedule,
                            table2_dfg, table3_area_tput)

    print("== Table I: gradient schedule trace ==")
    t1 = _timeit(table1_schedule.main, 1)
    print("== Table II: DFG characteristics ==")
    t2 = _timeit(table2_dfg.main, 1)
    print("== Table III: area & throughput ==")
    t3 = _timeit(table3_area_tput.main, 1)
    print("== Fig. 5: FUs required ==")
    t35 = _timeit(fig5_fus.main, 1)
    print("== Context switch (Section V) ==")
    t4 = _timeit(context_switch.main, 1)
    print("== Area analogue (TM vs spatial compiled size) ==")
    t5 = _timeit(area_analogue.main, 1)
    print("== Multi-tenant serving (context bank) ==")
    t7 = _timeit(multi_tenant.main, 1)
    print("== Roofline (from dry-run artifacts) ==")
    try:
        t6 = _timeit(roofline.main, 1)
    except Exception as e:
        print(f"(roofline artifacts unavailable: {e})")
        t6 = 0.0
    print("name,us_per_call,derived")
    print(f"table1_schedule,{t1:.0f},II=11")
    print(f"table2_dfg,{t2:.0f},8/8 exact")
    print(f"table3_area_tput,{t3:.0f},8/8 exact; max area savings >84%")
    print(f"fig5_fus,{t35:.0f},TM FUs = depth vs SCFU = ops")
    print(f"context_switch,{t4:.0f},worst ctx <0.35us @300MHz")
    print(f"area_analogue,{t5:.0f},tm executor vs spatial programs")
    print(f"multi_tenant,{t7:.0f},bank beats per-call load + recompile")
    print(f"roofline,{t6:.0f},per-cell three-term table")


if __name__ == "__main__":
    main()
