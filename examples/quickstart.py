"""Quickstart: map a compute kernel onto the TM-FU overlay and run it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full flow on the 'gradient' kernel (Fig. 1 / Table I):
C-like source -> DFG -> ASAP schedule (+ bypass insertion) -> 32-bit
instruction encoding -> execution on the compile-once overlay executor,
plus the analytical area/II/context models.
"""

import numpy as np

from repro.core import build_dfg, compile_program, dfg_eval, Overlay
from repro.core.area import area_eslices, throughput_gops
from repro.core.schedule import schedule

SRC = """
d1 = m1 - m3
d2 = m2 - m3
d3 = m3 - m4
d4 = m3 - m5
s1 = d1 * d1
s2 = d2 * d2
s3 = d3 * d3
s4 = d4 * d4
a1 = s1 + s2
a2 = s3 + s4
out = a1 + a2
"""


def main():
    dfg = build_dfg("gradient", ["m1", "m2", "m3", "m4", "m5"], SRC, ["out"])
    sch = schedule(dfg)
    print(f"DFG: {dfg.stats()}")
    print(f"schedule: {sch.n_fus} FUs, II={sch.ii} "
          f"(single-FU II={sch.single_fu_ii}, spatial FUs={sch.spatial_fus})")
    print(f"area: {area_eslices(sch.n_fus)} e-Slices "
          f"(spatial would need {area_eslices(sch.spatial_fus)})")
    print(f"throughput: {throughput_gops(dfg.n_ops, sch.ii):.2f} GOPS "
          f"@300MHz")
    kernel = compile_program(dfg)
    print(f"context: {kernel.program.context_bytes} B, "
          f"switch {kernel.program.context_switch_us():.3f} us @300MHz")
    print("\nfirst cycles of the pipeline schedule (Table I):")
    for cyc, acts in sch.cycle_trace(n_iters=1)[:12]:
        print(f"  cycle {cyc:3d}: "
              + "  ".join(f"FU{k}:{v}" for k, v in sorted(acts.items())))

    ov = Overlay()                     # 'configure the FPGA' once
    ctx = ov.load(kernel)              # context switch: ~bytes, no compile
    rng = np.random.RandomState(0)
    xs = [rng.randn(1024).astype(np.float32) for _ in range(5)]
    (y,) = ov(ctx, xs)
    import jax.numpy as jnp
    ref = dfg_eval(dfg, {n: jnp.asarray(v)
                         for n, v in zip(dfg.inputs, xs)})["out"]
    err = float(np.max(np.abs(np.asarray(y) - np.asarray(ref))))
    print(f"\noverlay vs oracle max|err| = {err:.2e} over 1024 iterations")
    assert err < 1e-5


if __name__ == "__main__":
    main()
