"""The paper's linear TM-FU pipeline carrying real transformer stages.

    PYTHONPATH=src python examples/pipeline_lm.py

Maps a 4-stage decoder onto a 4-device ring (simulated via
--xla_force_host_platform_device_count): stage s = FU s, time-multiplexed
over its layer slice; microbatches stream through ppermute neighbour
links; output checked against sequential execution; the paper's II model
is printed for the chosen (M, S).
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def main():
    from repro.models.layers import (AttnDims, attention_apply,
                                     init_attention, init_mlp, init_norm,
                                     mlp_apply, rms_norm)
    from repro.runtime.pipeline import (pipeline_apply, pipeline_ii,
                                        pipeline_reference)

    S_STAGES, M, mb, seq, d = 4, 8, 2, 32, 64
    dims = AttnDims(4, 2, 16)
    mesh = jax.make_mesh((S_STAGES,), ("stage",))

    def init_stage(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"ln1": init_norm(k1, d), "attn": init_attention(k2, d, dims),
                "ln2": init_norm(k3, d), "mlp": init_mlp(k4, d, 4 * d)}

    keys = jax.random.split(jax.random.PRNGKey(0), S_STAGES)
    stage_params = jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[init_stage(k) for k in keys])

    def stage_fn(p, h):
        pos = jnp.broadcast_to(jnp.arange(seq)[None], h.shape[:2])
        h = h + attention_apply(p["attn"], rms_norm(p["ln1"], h), dims=dims,
                                positions=pos, causal=True)
        return h + mlp_apply(p["mlp"], rms_norm(p["ln2"], h))

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, seq, d),
                          jnp.float32) * 0.1
    y = pipeline_apply(mesh, stage_fn, stage_params, x)
    ref = pipeline_reference(stage_fn, stage_params, x)
    err = float(jnp.max(jnp.abs(y - ref)))
    ii = pipeline_ii(M, S_STAGES)
    print(f"{S_STAGES}-stage transformer pipeline on a device ring: "
          f"max|err| vs sequential = {err:.2e}")
    print(f"II model: {ii['slots']} slots for {M} microbatches, "
          f"bubble {ii['bubble_fraction']:.1%}, "
          f"II/output {ii['ii_per_output']:.3f} "
          f"(paper: replication drives II -> 1)")
    assert err < 5e-4


if __name__ == "__main__":
    main()
