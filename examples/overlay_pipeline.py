"""Context-switching the whole benchmark suite on ONE overlay executor.

    PYTHONPATH=src python examples/overlay_pipeline.py [--pallas]

Compiles the overlay once, then streams all 8 paper kernels through it
back-to-back — each kernel change is a pure data swap (the paper's 0.27us
daisy-chain analogue).  With --pallas the TMFU Pallas kernel (interpret
mode on CPU; Mosaic on real TPU) executes the same contexts.
"""

import argparse
import time

import numpy as np

from repro.core import Overlay, compile_program, dfg_eval
from repro.core.paper_bench import BENCH_NAMES, benchmark


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--batch", type=int, default=4096)
    args = ap.parse_args()
    ov = Overlay(backend="pallas" if args.pallas else "jnp")
    rng = np.random.RandomState(0)
    kernels = {n: compile_program(benchmark(n)) for n in BENCH_NAMES}
    print(f"backend={ov.backend}  batch={args.batch}")
    print("kernel,ii,fus,ctx_bytes,swap+run_ms,max_err")
    for name, k in kernels.items():
        xs = [rng.uniform(-1, 1, args.batch).astype(np.float32)
              for _ in k.dfg.inputs]
        t0 = time.perf_counter()
        ctx = ov.load(k)               # context switch
        ys = ov(ctx, xs)               # stream the batch through
        np.asarray(ys[0])
        dt = (time.perf_counter() - t0) * 1e3
        import jax.numpy as jnp
        ref = dfg_eval(k.dfg, {n: jnp.asarray(v)
                               for n, v in zip(k.dfg.inputs, xs)})
        err = max(float(np.max(np.abs(np.asarray(y) - np.asarray(ref[o]))))
                  for y, o in zip(ys, k.dfg.outputs))
        print(f"{name},{k.sched.ii},{k.sched.n_fus},"
              f"{k.program.context_bytes},{dt:.1f},{err:.2e}")
        assert err < 1e-4


if __name__ == "__main__":
    main()
