"""End-to-end training driver.

    PYTHONPATH=src python examples/train_e2e.py            # ~10M, 200 steps
    PYTHONPATH=src python examples/train_e2e.py --preset 100m --steps 300

Drives the production launcher (repro.launch.train): sharded params/opt,
scanned+remat'd stacks, AdamW+cosine, async checkpoints, deterministic
resumable data.  The 100m preset matches the assignment's "~100M model for
a few hundred steps" (sized for real hardware; the default preset keeps
CPU wall-time sane and exercises the identical code path).
"""

import argparse
import sys

sys.path.insert(0, "src")


def build_preset(name: str):
    import dataclasses
    from repro.models import ModelConfig, dense_stacks

    if name == "10m":
        return ModelConfig(
            name="e2e-10m", d_model=256, n_heads=8, n_kv_heads=4,
            d_ff=1024, vocab=8192, head_dim=32,
            stacks=dense_stacks(4), full_attention=True)
    if name == "100m":
        return ModelConfig(
            name="e2e-100m", d_model=768, n_heads=12, n_kv_heads=12,
            d_ff=3072, vocab=32768, head_dim=64,
            stacks=dense_stacks(12), full_attention=True)
    raise SystemExit(f"unknown preset {name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.distributed import checkpoint as C
    from repro.models import init_params
    from repro.runtime import optim as O
    from repro.runtime.steps import make_train_step

    cfg = build_preset(args.preset)
    print(f"{cfg.name}: ~{cfg.param_count():,} params")
    oc = O.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    dc = DataConfig(global_batch=args.batch, seq_len=args.seq,
                    vocab=cfg.vocab)
    corpus = SyntheticCorpus(dc)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = O.init_opt(params)
    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnums=(0, 1))
    ckpt = C.AsyncCheckpointer(args.ckpt_dir)
    losses = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        params, opt, m = step_fn(params, opt, corpus.batch(step))
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tps = (step + 1) * args.batch * args.seq / dt
            print(f"step {step:4d} loss {losses[-1]:7.4f} "
                  f"({tps:,.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, (params, opt),
                            extra=corpus.cursor(step + 1))
    ckpt.wait()
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.2 else 'check hyperparams'})")
    assert last < first, "training diverged"


if __name__ == "__main__":
    main()
