"""Batched serving example: prefill + streaming greedy decode.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-4b
"""

import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    from repro.launch.serve import main as serve_main
    return serve_main(["--arch", args.arch, "--smoke",
                       "--batch", str(args.batch),
                       "--prompt-len", str(args.prompt_len),
                       "--gen", str(args.gen)])


if __name__ == "__main__":
    sys.exit(main())
