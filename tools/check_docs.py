"""Docs checker: validate markdown links and code references.

Checks every tracked ``*.md`` file:

* relative links (``[text](path)`` and ``[text](path#anchor)``) must point
  at files that exist (http/https/mailto links are skipped);
* backtick references to repo paths like ``src/repro/core/bank.py`` or
  ``benchmarks/multi_tenant.py`` must exist.

Run: python tools/check_docs.py   (exit code 1 on any broken reference)
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools)/[\w./-]+\.\w+)`")


def check_file(md: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    in_fence = False
    for ln, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not (md.parent / path).exists():
                errors.append(f"{md.relative_to(ROOT)}:{ln}: "
                              f"broken link -> {target}")
        for ref in CODE_PATH_RE.findall(line):
            if not (ROOT / ref).exists():
                errors.append(f"{md.relative_to(ROOT)}:{ln}: "
                              f"missing code path -> {ref}")
    return errors


def main() -> int:
    mds = [p for p in ROOT.rglob("*.md")
           if "__pycache__" not in p.parts and ".git" not in p.parts]
    errors = []
    for md in sorted(mds):
        errors.extend(check_file(md))
    for e in errors:
        print(e)
    print(f"checked {len(mds)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
