"""Docs checker: validate markdown links, anchors, and code references.

Checks every tracked ``*.md`` file:

* relative links (``[text](path)`` and ``[text](path#anchor)``) must point
  at files that exist (http/https/mailto links are skipped);
* anchor fragments (``path#anchor`` and same-page ``#anchor``) must match
  a heading in the target file (GitHub slugification: lowercase, drop
  punctuation, spaces to hyphens) — a renamed section breaks its inbound
  links silently otherwise;
* backtick references to repo paths like ``src/repro/core/bank.py`` or
  ``benchmarks/multi_tenant.py`` must exist.

Run: python tools/check_docs.py   (exit code 1 on any broken reference)
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH_RE = re.compile(
    r"`((?:src|docs|tests|benchmarks|examples|tools)/[\w./-]+\.\w+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a markdown heading (close enough for our
    ASCII headings): drop markup/punctuation, lowercase, hyphenate."""
    text = heading.strip().replace("`", "")
    text = re.sub(r"[^\w\s-]", "", text).strip().lower()
    return re.sub(r"[\s]+", "-", text)


def heading_slugs(md: pathlib.Path, cache: dict) -> set[str]:
    slugs = cache.get(md)
    if slugs is None:
        slugs = set()
        in_fence = False
        for line in md.read_text(encoding="utf-8").splitlines():
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            m = None if in_fence else HEADING_RE.match(line)
            if m:
                base = github_slug(m.group(1))
                # GitHub dedupes repeats as slug-1, slug-2, ...
                slug, n = base, 1
                while slug in slugs:
                    slug = f"{base}-{n}"
                    n += 1
                slugs.add(slug)
        cache[md] = slugs
    return slugs


def check_file(md: pathlib.Path, slug_cache: dict) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    in_fence = False
    for ln, line in enumerate(text.splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in LINK_RE.findall(line):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path, _, anchor = target.partition("#")
            dest = md if not path else (md.parent / path).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}:{ln}: "
                              f"broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if anchor not in heading_slugs(dest, slug_cache):
                    errors.append(f"{md.relative_to(ROOT)}:{ln}: "
                                  f"broken anchor -> {target}")
        for ref in CODE_PATH_RE.findall(line):
            if not (ROOT / ref).exists():
                errors.append(f"{md.relative_to(ROOT)}:{ln}: "
                              f"missing code path -> {ref}")
    return errors


def main() -> int:
    mds = [p for p in ROOT.rglob("*.md")
           if "__pycache__" not in p.parts and ".git" not in p.parts]
    errors = []
    slug_cache: dict = {}
    for md in sorted(mds):
        errors.extend(check_file(md, slug_cache))
    for e in errors:
        print(e)
    print(f"checked {len(mds)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
