"""Record the DRR golden trace: round composition + result digests.

Replays a fixed multi-tenant submission trace through ``OverlayServer``
and writes ``tests/golden/drr_rounds.json``:

* ``rounds`` — the exact ticket composition of every DRR round, in
  formation order (intra-round order is the policy's take order);
* ``digests`` — sha1 of each ticket's concatenated f32 output bytes.

The file is the bit-for-bit extraction oracle for
``repro.sched.rounds.DeficitRoundRobin`` (tests/test_sched_policies.py):
the policy-driven engine must form IDENTICAL rounds and serve IDENTICAL
bytes on this trace.  Regenerate only when the trace itself is changed
deliberately — never to paper over a behavioural drift::

    PYTHONPATH=src python tools/record_golden_rounds.py
"""

from __future__ import annotations

import hashlib
import json
import pathlib

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT = ROOT / "tests" / "golden" / "drr_rounds.json"

#: trace shape — mirrored in tests/test_sched_policies.py
TRACE_SEED = 1234
TRACE_REQUESTS = 28
TRACE_TENANTS = 4
TRACE_BATCHES = (48, 96, 160, 256)
SERVER_KW = dict(bank_capacity=4, round_kernels=2, max_inflight=2,
                 quantum_tiles=2.0, tile=64)


def build_trace(kernels):
    """Deterministic (tenant, kernel, xs) list — the recorded submissions."""
    rng = np.random.RandomState(TRACE_SEED)
    names = sorted(kernels)
    trace = []
    for i in range(TRACE_REQUESTS):
        name = names[int(rng.randint(len(names)))]
        k = kernels[name]
        batch = int(TRACE_BATCHES[int(rng.randint(len(TRACE_BATCHES)))])
        xs = [rng.uniform(-2, 2, (batch,)).astype(np.float32)
              for _ in k.dfg.inputs]
        trace.append((f"tenant{i % TRACE_TENANTS}", name, xs))
    return trace


def replay(srv, trace, kernels):
    """Submit the trace, spy on round formation, drain; returns
    (rounds-as-ticket-lists, {ticket: sha1-of-output-bytes})."""
    rounds: list[list[int]] = []
    orig = srv._form_round

    def spy():
        reqs = orig()
        if reqs is not None:
            rounds.append([r.ticket for r in reqs])
        return reqs

    srv._form_round = spy
    for tenant, name, xs in trace:
        srv.submit(kernels[name], xs, tenant=tenant)
    results = srv.flush()
    digests = {}
    for t, outs in results.items():
        h = hashlib.sha1()
        for y in outs:
            h.update(np.ascontiguousarray(np.asarray(y, np.float32)).tobytes())
        digests[int(t)] = h.hexdigest()
    return rounds, digests


def main() -> int:
    from repro.core.overlay import compile_program
    from repro.core.paper_bench import BENCH_NAMES, benchmark
    from repro.launch.serve import OverlayServer

    kernels = {n: compile_program(benchmark(n))
               for n in BENCH_NAMES + ("gradient",)}
    trace = build_trace(kernels)
    srv = OverlayServer(**SERVER_KW)
    rounds, digests = replay(srv, trace, kernels)
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(
        {"seed": TRACE_SEED, "requests": TRACE_REQUESTS,
         "tenants": TRACE_TENANTS, "batches": list(TRACE_BATCHES),
         "server": {k: v for k, v in SERVER_KW.items()},
         "rounds": rounds,
         "digests": {str(t): d for t, d in sorted(digests.items())}},
        indent=1) + "\n")
    print(f"wrote {OUT}: {len(rounds)} rounds, {len(digests)} tickets")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
