#!/usr/bin/env python
"""Cross-PR benchmark trajectory: accumulate CI bench rows, gate regressions.

CI's bench-smoke job writes one JSON row per serving study into
``artifacts/bench/*.json`` (sharded / steal / autoscale / gateway) and
uploads them as build artifacts — but artifacts evaporate with the run,
so until now nothing compared one PR's throughput against the last.
This tool closes that loop with a COMMITTED ledger:

``append``
    Read every ``artifacts/bench/*.json`` row, extract that benchmark's
    headline throughput metric, and append an entry keyed by
    ``(git sha, benchmark name)`` to ``BENCH_trajectory.json``.  The key
    makes appends idempotent: re-running CI on the same sha updates the
    sha's entry in place instead of duplicating it.

``check``
    For each benchmark present in the ledger, compare the NEWEST entry
    against the previous entry from a DIFFERENT sha.  Exit non-zero if
    throughput regressed more than ``--tolerance`` (default 15%) — the
    CI gate.  Benchmarks with fewer than two shas pass vacuously (first
    PR to add a lane seeds its own baseline).

``show``
    Print the per-benchmark trajectory as a table (sha, value, delta).

The ledger only holds the slim headline metrics (throughput + a couple
of shape fields), not the full rows — full rows stay in the per-run CI
artifacts.  Keep ``BENCH_trajectory.json`` committed; CI appends on its
checkout to run the gate, and the human lands the refreshed ledger with
the PR (same model as a lockfile).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_LEDGER = os.path.join(REPO, "BENCH_trajectory.json")
DEFAULT_ARTIFACTS = os.path.join(REPO, "artifacts", "bench")

#: benchmark name (artifact file stem) -> (headline metric key,
#: context keys copied alongside for reading the ledger without the run
#: [, direction]).  Direction defaults to "higher" (throughput-style:
#: the gate fails when the value DROPS beyond tolerance); "lower" flips
#: the gate for latency-style headlines (fails when the value RISES).
METRICS = {
    "sharded": ("sharded_rps", ("replicas", "devices", "speedup")),
    "steal": ("steal_rps", ("replicas", "devices", "speedup")),
    "autoscale": ("elastic_rps",
                  ("max_replicas", "devices", "throughput_ratio",
                   "idle_replica_slices_saved")),
    "gateway": ("gateway_rps",
                ("connections", "replicas", "n_shed", "n_edge_queued",
                 "peak_fleet_tiles")),
    "loopback": ("loopback_rps",
                 ("connections", "codec", "framing_tax", "inproc_rps",
                  "wire_frames_in", "wire_bytes_out")),
    "slo": ("slo_attainment",
            ("latency_p99_ms", "bulk_p99_ms", "flat_latency_p99_ms",
             "policy", "quantum_tiles", "lat_quantum", "configs")),
    "hot_path": ("hotpath_rps",
                 ("g_total", "tile", "assemble_speedup", "collect_speedup",
                  "stage_speedup", "assemble_gbps", "retraces")),
    "train_serve": ("train_steps_per_s_cosched",
                    ("serve_p99_under_train_ms", "serve_p99_dedicated_ms",
                     "p99_degrade_frac", "cosched_efficiency",
                     "train_steps", "preemptions")),
    "train_serve_p99": ("serve_p99_under_train",
                        ("serve_p99_dedicated_ms", "p99_degrade_frac"),
                        "lower"),
}


def _metric(name):
    """Normalise a METRICS entry to ``(metric, extras, direction)``."""
    entry = METRICS.get(name)
    if entry is None:
        return None, (), "higher"
    return entry if len(entry) == 3 else (*entry, "higher")


def git_sha(short: bool = True) -> str:
    cmd = ["git", "rev-parse"] + (["--short"] if short else []) + ["HEAD"]
    out = subprocess.run(cmd, cwd=REPO, capture_output=True, text=True,
                         check=True)
    return out.stdout.strip()


def load_ledger(path: str) -> dict:
    if not os.path.exists(path):
        return {"benchmarks": {}}
    with open(path) as f:
        ledger = json.load(f)
    ledger.setdefault("benchmarks", {})
    return ledger


def save_ledger(path: str, ledger: dict) -> None:
    with open(path, "w") as f:
        json.dump(ledger, f, indent=1, sort_keys=True)
        f.write("\n")


def append(args) -> int:
    ledger = load_ledger(args.ledger)
    sha = args.sha or git_sha()
    paths = sorted(glob.glob(os.path.join(args.artifacts, "*.json")))
    if not paths:
        print(f"bench_trajectory: no rows under {args.artifacts}; "
              f"nothing to append", file=sys.stderr)
        return 0 if args.allow_empty else 1
    n = 0
    for path in paths:
        name = os.path.splitext(os.path.basename(path))[0]
        if name not in METRICS:
            print(f"  skip {name}: no metric mapping "
                  f"(known: {sorted(METRICS)})")
            continue
        metric, extras, _ = _metric(name)
        with open(path) as f:
            row = json.load(f)
        if metric not in row:
            print(f"  skip {name}: row lacks {metric!r}", file=sys.stderr)
            continue
        entry = {"sha": sha, metric: row[metric]}
        entry.update({k: row[k] for k in extras if k in row})
        series = ledger["benchmarks"].setdefault(name, [])
        # idempotent on sha: a CI re-run refreshes in place
        series[:] = [e for e in series if e.get("sha") != sha]
        series.append(entry)
        n += 1
        print(f"  append {name}@{sha}: {metric}={row[metric]:.1f}")
    save_ledger(args.ledger, ledger)
    print(f"bench_trajectory: {n} entr{'y' if n == 1 else 'ies'} "
          f"-> {args.ledger}")
    return 0


def check(args) -> int:
    ledger = load_ledger(args.ledger)
    failures = []
    for name, series in sorted(ledger["benchmarks"].items()):
        if len(series) < 2:
            print(f"  {name}: {len(series)} entry — baseline only, pass")
            continue
        metric, _, direction = _metric(name)
        cur = series[-1]
        prev = next((e for e in reversed(series[:-1])
                     if e.get("sha") != cur.get("sha")), None)
        if prev is None:
            print(f"  {name}: only one sha recorded, pass")
            continue
        if metric is None or metric not in cur or metric not in prev:
            print(f"  {name}: metric missing, pass", file=sys.stderr)
            continue
        if direction == "lower":
            bound = prev[metric] * (1.0 + args.tolerance)
            ok = cur[metric] <= bound
            word = "ceiling"
        else:
            bound = prev[metric] * (1.0 - args.tolerance)
            ok = cur[metric] >= bound
            word = "floor"
        print(f"  {name}: {prev[metric]:.1f} ({prev['sha']}) -> "
              f"{cur[metric]:.1f} ({cur['sha']}) "
              f"[{word} {bound:.1f}] {'ok' if ok else 'REGRESSION'}")
        if not ok:
            failures.append(name)
    if failures:
        print(f"bench_trajectory: headline regressed >"
              f"{args.tolerance:.0%} on: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    print("bench_trajectory: no regressions beyond tolerance")
    return 0


def show(args) -> int:
    ledger = load_ledger(args.ledger)
    if not ledger["benchmarks"]:
        print("bench_trajectory: ledger is empty")
        return 0
    for name, series in sorted(ledger["benchmarks"].items()):
        metric, _, _ = _metric(name)
        print(f"{name} ({metric}):")
        prev_v = None
        for e in series:
            v = e.get(metric)
            delta = ("" if prev_v is None or v is None
                     else f"  {(v / prev_v - 1.0):+.1%}")
            print(f"  {e.get('sha', '?'):>12}  "
                  f"{v if v is None else format(v, '.1f'):>10}{delta}")
            prev_v = v if v is not None else prev_v
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ledger", default=DEFAULT_LEDGER,
                    help="trajectory JSON path (committed)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    a = sub.add_parser("append", help="fold artifacts/bench rows in")
    a.add_argument("--artifacts", default=DEFAULT_ARTIFACTS)
    a.add_argument("--sha", default=None,
                   help="override git sha (default: HEAD short sha)")
    a.add_argument("--allow-empty", action="store_true",
                   help="exit 0 when no artifact rows exist")
    a.set_defaults(fn=append)

    c = sub.add_parser("check", help="gate on throughput regressions")
    c.add_argument("--tolerance", type=float, default=0.15,
                   help="max allowed fractional drop vs previous sha")
    c.set_defaults(fn=check)

    s = sub.add_parser("show", help="print the trajectory")
    s.set_defaults(fn=show)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
